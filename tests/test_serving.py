"""Learned-cost serving behind the cache seam (``engine/serving.py``):
online trainer harvest/refit, hybrid routing with analytic fallback,
single-forward-pass miss-batch pricing in lockstep rounds, exact-analytic
bit-identity, and the worker version-tag protocol."""
import pickle
import random

import pytest
from conftest import DECODE_CELL, make_cell_mdp

from repro.core.engine import (
    ArrayMCTS,
    CachedMDP,
    HybridCostBackend,
    OnlineCostTrainer,
    TranspositionCache,
    make_cost_backend,
)
from repro.core.engine.batch import run_decision_batch
from repro.core.ensemble import ProTuner
from repro.core.mcts import MCTSConfig
from repro.core.mdp import ScheduleMDP


def _mdp(arch=DECODE_CELL[0], shape=DECODE_CELL[1]) -> ScheduleMDP:
    return make_cell_mdp(arch, shape)


def _backend(space, mode="hybrid", audit_every=8, **kw):
    kw.setdefault("min_examples", 32)
    kw.setdefault("refit_every", 64)
    kw.setdefault("steps", 30)
    return HybridCostBackend(
        space, mode=mode, audit_every=audit_every,
        trainer=OnlineCostTrainer(space, **kw),
    )


def _warm(cmdp, n=48, seed=0):
    """Fill the cache with analytic-priced random terminals."""
    rng = random.Random(seed)
    states = [tuple(cmdp.space.random_actions(rng)) for _ in range(n)]
    cmdp.terminal_cost_batch(states)
    return states


# ---------------------------------------------------------------------------
# trainer: harvest + refit
# ---------------------------------------------------------------------------
def test_trainer_harvests_analytic_entries_and_fits():
    mdp = _mdp()
    be = _backend(mdp.space)
    cmdp = CachedMDP(mdp, cost_backend=be)
    _warm(cmdp, n=31)  # one short of min_examples
    assert be.trainer.model is None and not be.trainer.should_fit(cmdp.cache)
    _warm(cmdp, n=8, seed=1)
    assert be.trainer.should_fit(cmdp.cache)
    cmdp.on_round_end()  # the deterministic refit boundary
    assert be.trainer.model is not None, "refit point crossed but no fit"
    assert be.trainer.version == 1 and be.model.version == 1
    rep = be.trainer.reports[-1]
    assert rep.n_examples >= 32 and rep.n_holdout > 0
    # harvest excludes nothing yet: no learned entries exist
    states, costs = be.trainer.harvest(cmdp.cache)
    assert len(states) == len(cmdp.cache.terminal)
    assert all(cmdp.cache.terminal[s] == c for s, c in zip(states, costs))


def test_trainer_never_trains_on_learned_entries():
    mdp = _mdp()
    # always serve; audits off so every batch is model-priced
    be = _backend(mdp.space, confidence_threshold=-1.0, audit_every=0)
    cmdp = CachedMDP(mdp, cost_backend=be)
    _warm(cmdp, n=40)
    cmdp.on_round_end()
    assert be.trainer.confident
    # these misses are model-priced and tagged...
    learned_states = _warm(cmdp, n=20, seed=2)
    new_tags = [s for s in learned_states if s in cmdp.cache.terminal_version]
    assert new_tags, "confident model did not serve"
    assert all(
        cmdp.cache.terminal_version[s] == be.model.version for s in new_tags
    )
    # ...and the next harvest must skip every one of them
    states, _ = be.trainer.harvest(cmdp.cache)
    assert not set(states) & set(cmdp.cache.terminal_version)


def test_unconfident_fit_backs_off_refits():
    mdp = _mdp()
    be = _backend(mdp.space, confidence_threshold=2.0)  # can never pass
    cmdp = CachedMDP(mdp, cost_backend=be)
    _warm(cmdp, n=40)
    cmdp.on_round_end()
    assert be.trainer.model is not None and not be.trainer.confident
    assert be.trainer._interval == 128  # doubled from refit_every=64
    # unconfident model must NOT serve in hybrid mode: everything analytic
    assert not cmdp.cache.terminal_version
    assert be.n_learned_batches == 0


# ---------------------------------------------------------------------------
# hybrid routing
# ---------------------------------------------------------------------------
def test_untrained_backend_prices_exactly_like_analytic():
    mdp = _mdp()
    plain = CachedMDP(_mdp())
    be = _backend(mdp.space, min_examples=10**9)  # never fits
    hybrid = CachedMDP(mdp, cost_backend=be)
    rng = random.Random(3)
    states = [tuple(mdp.space.random_actions(rng)) for _ in range(16)]
    assert hybrid.terminal_cost_batch(states) == plain.terminal_cost_batch(states)
    prefixes = [s[:4] for s in states]
    assert hybrid.partial_cost_batch(prefixes) == plain.partial_cost_batch(prefixes)
    assert (hybrid.cache.hits, hybrid.cache.misses) == (
        plain.cache.hits, plain.cache.misses)
    assert not hybrid.cache.terminal_version
    assert be.n_analytic_plans > 0 and be.n_learned_plans == 0


def test_scalar_misses_route_through_backend():
    mdp = _mdp()
    be = _backend(mdp.space, confidence_threshold=-1.0, audit_every=0)
    cmdp = CachedMDP(mdp, cost_backend=be)
    _warm(cmdp, n=40)
    cmdp.on_round_end()
    rng = random.Random(9)
    s = tuple(mdp.space.random_actions(rng))
    while s in cmdp.cache.terminal:
        s = tuple(mdp.space.random_actions(rng))
    f0 = be.model.n_forward
    c = cmdp.terminal_cost(s)
    assert cmdp.cache.terminal[s] == c
    assert cmdp.cache.terminal_version[s] == be.model.version
    assert be.model.n_forward == f0 + 1
    # partial prefix, too
    p = s[:3]
    cp = cmdp.partial_cost(p)
    assert cmdp.cache.partial[p] == cp
    assert cmdp.cache.partial_version[p] == be.model.version


def test_audit_stream_keeps_training_alive_while_serving():
    mdp = _mdp()
    be = _backend(mdp.space, confidence_threshold=-1.0, audit_every=2)
    cmdp = CachedMDP(mdp, cost_backend=be)
    _warm(cmdp, n=40)
    cmdp.on_round_end()
    assert be.trainer.confident
    n_analytic0 = be.trainer.n_analytic(cmdp.cache)
    # serving-era miss batches: each audits iff the stateless content hash
    # selects it — deterministic, process-independent, ~1/audit_every
    rng = random.Random(23)
    audited = served = 0
    for _ in range(24):
        s = tuple(mdp.space.random_actions(rng))
        while s in cmdp.cache.terminal:
            s = tuple(mdp.space.random_actions(rng))
        expect_audit = be.audit_batch([s])
        cmdp.terminal_cost_batch([s])
        tagged = s in cmdp.cache.terminal_version
        assert tagged == (not expect_audit)
        audited += expect_audit
        served += not expect_audit
    assert audited > 0 and served > 0
    # audited entries are exact, untagged, and harvestable: the analytic
    # stream keeps growing, so a later refit (and gate re-check) can fire
    assert be.trainer.n_analytic(cmdp.cache) == n_analytic0 + audited
    assert be.n_analytic_plans > 0
    # a pickled (worker) copy makes identical audit decisions
    worker = pickle.loads(pickle.dumps(cmdp)).cost_backend
    probe = [tuple(mdp.space.random_actions(rng)) for _ in range(16)]
    assert [worker.audit_batch([s]) for s in probe] == [
        be.audit_batch([s]) for s in probe]


def test_refit_evicts_superseded_predictions():
    mdp = _mdp()
    be = _backend(mdp.space, confidence_threshold=-1.0, refit_every=8,
                  audit_every=0)
    cmdp = CachedMDP(mdp, cost_backend=be)
    _warm(cmdp, n=40)
    cmdp.on_round_end()
    assert be.trainer.version == 1
    served = [s for s in _warm(cmdp, n=15, seed=5)
              if s in cmdp.cache.terminal_version]
    assert served  # v1 predictions are cached
    # drop the model: the next pricing boundary refits (analytic count is
    # past min_examples), evicts every v1 prediction, then serves v2
    be.trainer.model = None
    rng = random.Random(6)
    extra = []
    while len(extra) < 9:
        s = tuple(mdp.space.random_actions(rng))
        if s not in cmdp.cache.terminal:
            extra.append(s)
    cmdp.terminal_cost_batch(extra)
    assert be.trainer.version == 2
    # every v1 prediction is gone — repriced on next lookup, never served
    # as a stale hit; everything tagged now is v2
    assert all(s not in cmdp.cache.terminal for s in served)
    assert cmdp.cache.terminal_version
    assert all(v == 2 for v in cmdp.cache.terminal_version.values())
    c = cmdp.terminal_cost(served[0])  # reprice with the v2 model
    assert cmdp.cache.terminal_version[served[0]] == 2
    assert c > 0


def test_holdout_split_is_persistent_and_disjoint_from_training():
    mdp = _mdp()
    be = _backend(mdp.space)
    tr = be.trainer
    rng = random.Random(31)
    states = [tuple(mdp.space.random_actions(rng)) for _ in range(64)]
    first = [tr.is_holdout(s) for s in states]
    assert any(first) and not all(first)
    tr.version += 3  # the split must NOT depend on the fit generation
    assert [tr.is_holdout(s) for s in states] == first
    # pickled (worker) trainers agree too
    assert [pickle.loads(pickle.dumps(tr)).is_holdout(s) for s in states] == first


def test_make_cost_backend_modes():
    space = _mdp().space
    assert make_cost_backend("analytic", space) is None
    assert make_cost_backend(None, space) is None
    assert make_cost_backend("learned", space).mode == "learned"
    be = _backend(space)
    assert make_cost_backend(be, space) is be
    with pytest.raises(ValueError):
        make_cost_backend("compile", space)
    with pytest.raises(ValueError):
        HybridCostBackend(space, mode="analytic")


# ---------------------------------------------------------------------------
# the acceptance counter test: one model call per lockstep miss batch on
# the Table-1 decode cell
# ---------------------------------------------------------------------------
def test_lockstep_round_prices_miss_batch_in_one_forward_pass():
    mdp = _mdp("granite-3-2b", "decode_32k")
    # audits off: every miss batch must be exactly one model forward
    be = _backend(mdp.space, confidence_threshold=-1.0, audit_every=0)
    cmdp = CachedMDP(mdp, cost_backend=be)
    _warm(cmdp, n=40)  # train the server
    cmdp.on_round_end()
    assert be.model is not None
    iters, k = 6, 4
    import dataclasses

    cfg = MCTSConfig(ucb="paper", iters_per_decision=iters, seed=0)
    trees = [ArrayMCTS(cmdp, dataclasses.replace(cfg, seed=i))
             for i in range(k)]
    f0, b0 = be.model.n_forward, be.n_learned_batches
    hm0 = cmdp.cache.hits + cmdp.cache.misses
    run_decision_batch(trees, cmdp)
    forward = be.model.n_forward - f0
    batches = be.n_learned_batches - b0
    # every miss batch was priced in exactly ONE jitted forward pass, and
    # there is at most one miss batch per lockstep step — never one call
    # per leaf (k * iters would be the scalar-loop count)
    assert forward == batches
    assert 0 < forward <= iters
    assert forward < k * iters
    # the lockstep round still priced every leaf through the cache seam
    assert cmdp.cache.hits + cmdp.cache.misses - hm0 >= k * iters


def test_round_end_hook_refits_between_rounds():
    mdp = _mdp()
    be = _backend(mdp.space, min_examples=32, refit_every=10**9)
    cmdp = CachedMDP(mdp, cost_backend=be)
    _warm(cmdp, n=60)
    assert be.trainer.model is None  # refit checks fired before the data existed
    # the lockstep driver's round boundary is a refit point
    tree = ArrayMCTS(cmdp, MCTSConfig(iters_per_decision=2, seed=0))
    run_decision_batch([tree], cmdp)
    assert be.trainer.model is not None, "round-end hook did not refit"


# ---------------------------------------------------------------------------
# ProTuner integration
# ---------------------------------------------------------------------------
def test_protuner_analytic_mode_is_bit_identical_and_unmounted():
    def run(**kw):
        t = ProTuner(
            _mdp(), n_standard=2, n_greedy=1,
            mcts_config=MCTSConfig(iters_per_decision=8), seed=1, **kw,
        )
        res = t.run()
        return t, res

    t0, r0 = run()
    t1, r1 = run(cost="analytic")
    assert t1.mdp.cost_backend is None  # nothing mounted: the PR-2 path
    assert (r0.plan, r0.cost, [d["action"] for d in r0.decisions]) == (
        r1.plan, r1.cost, [d["action"] for d in r1.decisions])
    assert r1.cost_mode == "analytic" and r1.model_version == 0


def test_protuner_hybrid_falls_back_exactly_while_untrained():
    def run(cost):
        res = ProTuner(
            _mdp(), n_standard=2, n_greedy=1,
            mcts_config=MCTSConfig(iters_per_decision=8), seed=1, cost=cost,
        ).run()
        return res

    r_a = run("analytic")
    r_h = run(_backend(_mdp().space, min_examples=10**9))  # never trains
    assert (r_h.plan, r_h.cost) == (r_a.plan, r_a.cost)
    assert [d["action"] for d in r_h.decisions] == [
        d["action"] for d in r_a.decisions]
    assert r_h.cost_mode == "hybrid" and r_h.n_fits == 0


def test_protuner_hybrid_serves_and_reports():
    be = _backend(_mdp().space, confidence_threshold=-1.0)
    res = ProTuner(
        _mdp(), n_standard=2, n_greedy=1,
        mcts_config=MCTSConfig(iters_per_decision=16), seed=0, cost=be,
    ).run()
    assert res.cost_mode == "hybrid"
    assert res.n_fits >= 1 and res.model_version >= 1
    assert res.learned_evals > 0
    # reported cost is the EXACT analytic cost of the final plan, not the
    # model's estimate
    oracle = _mdp()
    assert res.cost == oracle.cost_model.cost(res.plan)


def test_protuner_rejects_hybrid_without_cache():
    with pytest.raises(ValueError):
        ProTuner(_mdp(), n_standard=1, n_greedy=0, cache=False, cost="hybrid")


def test_protuner_adopts_premounted_backend():
    # a backend already mounted on a passed-in CachedMDP is pricing misses
    # whatever cost= says — reporting and exact repricing must see it
    be = _backend(_mdp().space, confidence_threshold=-1.0)
    cmdp = CachedMDP(_mdp(), cost_backend=be)
    tuner = ProTuner(cmdp, n_standard=2, n_greedy=0,
                     mcts_config=MCTSConfig(iters_per_decision=16), seed=0)
    assert tuner.cost_backend is be and tuner.cost_mode == "hybrid"
    res = tuner.run()
    assert res.cost_mode == "hybrid" and res.learned_evals > 0
    assert res.cost == _mdp().cost_model.cost(res.plan)  # exact, not estimate


def test_reference_engine_serves_learned_cost():
    # cost backends imply the cache for ANY engine (the cache is the seam);
    # engine="reference" + cost="learned" must mount, not raise
    be = _backend(_mdp().space, mode="learned", min_examples=16)
    tuner = ProTuner(_mdp(), n_standard=1, n_greedy=0, engine="reference",
                     mcts_config=MCTSConfig(iters_per_decision=16), seed=0,
                     cost=be)
    assert isinstance(tuner.mdp, CachedMDP)
    res = tuner.run()
    assert res.cost_mode == "learned" and res.n_fits >= 1


# ---------------------------------------------------------------------------
# worker protocol: serve-only pickles, version tags survive merges
# ---------------------------------------------------------------------------
def test_pickled_backend_is_serve_only():
    mdp = _mdp()
    be = _backend(mdp.space, confidence_threshold=-1.0)
    cmdp = CachedMDP(mdp, cost_backend=be)
    _warm(cmdp, n=40)
    cmdp.on_round_end()
    v = be.model.version
    worker = pickle.loads(pickle.dumps(cmdp))
    wbe = worker.cost_backend
    assert wbe.refit_enabled is False and be.refit_enabled is True
    assert wbe.model.version == v
    # a worker prices new misses with the shipped model and tags them
    rng = random.Random(17)
    states = [tuple(mdp.space.random_actions(rng)) for _ in range(12)]
    worker.terminal_cost_batch(states)
    new = [s for s in states if s in worker.cache.terminal_version]
    assert new and all(worker.cache.terminal_version[s] == v for s in new)
    # trainer state untouched: no fits happened worker-side
    assert wbe.trainer.version == v


def test_params_ship_only_on_generation_change_and_evict_on_install():
    """The pinned-worker forward seam: ``params_delta`` is ``None`` while
    the fit generation is unchanged (nothing re-pickles round after
    round), ships ``(version, confident, model)`` exactly when a refit
    minted a new generation, and ``apply_params`` on the worker side
    mirrors the master's refit eviction before installing — stale
    predictions tagged by the superseded generation must not keep serving
    as hits."""
    mdp = _mdp()
    # steps=10: the protocol under test is version bookkeeping, not fit
    # quality — confidence_threshold=-1 serves whatever comes out
    be = _backend(mdp.space, confidence_threshold=-1.0, steps=10)
    cmdp = CachedMDP(mdp, cost_backend=be)
    assert be.params_delta(0) is None  # untrained: generation 0 everywhere
    _warm(cmdp, n=40)
    cmdp.on_round_end()  # master refit -> generation 1
    v = be.trainer.version
    assert v >= 1
    delta = be.params_delta(0)
    assert delta is not None
    assert delta[0] == v and delta[2] is be.trainer.model
    assert be.params_delta(v) is None  # same generation: nothing ships

    # worker holds generation v (the init snapshot) and serves with it
    worker = pickle.loads(pickle.dumps(cmdp))
    wbe = worker.cost_backend
    rng = random.Random(23)
    states = [tuple(mdp.space.random_actions(rng)) for _ in range(12)]
    worker.terminal_cost_batch(states)
    tagged = [s for s in states if s in worker.cache.terminal_version]
    assert tagged and all(
        worker.cache.terminal_version[s] == v for s in tagged
    )

    # master refits again -> generation v+1; the worker keeps serving the
    # old model until the delta arrives, then installs and evicts
    assert be.trainer.fit(cmdp.cache) is not None
    delta2 = be.params_delta(v)
    assert delta2 is not None and delta2[0] == v + 1
    assert wbe.trainer.version == v  # still the old generation
    wbe.apply_params(delta2)
    assert wbe.trainer.version == v + 1
    assert wbe.model is delta2[2]
    for s in tagged:  # superseded predictions evicted, repriced on lookup
        assert s not in worker.cache.terminal
    assert not worker.cache.terminal_version


def test_cache_merge_carries_version_tags():
    a, b = TranspositionCache(), TranspositionCache()
    a.terminal[(1, 2)] = 0.5
    b.terminal[(3, 4)] = 0.7
    b.terminal_version[(3, 4)] = 2
    b.partial[(3,)] = 0.9
    b.partial_version[(3,)] = 2
    a.merge(b)
    assert a.terminal_version == {(3, 4): 2}
    assert a.partial_version == {(3,): 2}
    st = a.stats()
    assert st["learned_terminal_entries"] == 1
    assert st["learned_partial_entries"] == 1


def test_cache_merge_exact_wins_over_predictions():
    # sibling workers race on state S: one audits it analytically (exact,
    # untagged), one serves the model (tagged) — exact must survive the
    # merge in BOTH orders
    def exact():
        c = TranspositionCache()
        c.terminal[(7, 7)] = 1.0  # the exact analytic value
        return c

    def predicted():
        c = TranspositionCache()
        c.terminal[(7, 7)] = 1.1  # a model prediction
        c.terminal_version[(7, 7)] = 3
        return c

    a = exact()
    a.merge(predicted())
    assert a.terminal[(7, 7)] == 1.0 and not a.terminal_version

    b = predicted()
    b.merge(exact())
    assert b.terminal[(7, 7)] == 1.0 and not b.terminal_version


def test_small_first_fit_never_trains_on_holdout_states():
    mdp = _mdp()
    be = _backend(mdp.space, min_examples=10, refit_every=10**9)
    tr = be.trainer
    cmdp = CachedMDP(mdp, cost_backend=be)
    # a snapshot small enough that the holdout slice (<8) cannot be scored
    rng = random.Random(41)
    states = []
    while len(states) < 12:
        s = tuple(mdp.space.random_actions(rng))
        if s not in states:
            states.append(s)
    n_marked = sum(tr.is_holdout(s) for s in states)
    assert n_marked > 0  # some ARE holdout-marked
    cmdp.terminal_cost_batch(states)
    cmdp.on_round_end()
    rep = tr.reports[-1]
    # uncertified (no scorable holdout) AND holdout-marked states sat out
    # of training entirely — they never leak into the warm-started params
    assert rep.n_holdout == 0 and not tr.confident
    assert rep.n_examples == len(states)
    assert rep.n_train == len(states) - n_marked < len(states)


@pytest.mark.slow
def test_parallel_hybrid_merges_worker_tags_and_counters():
    # "learned" mode: serve as soon as the master's round-end fit exists
    # (the tiny first-round snapshot has no holdout, so hybrid's gate
    # would stay closed — gate behavior is covered sequentially above)
    be = _backend(_mdp().space, mode="learned", min_examples=16)
    tuner = ProTuner(
        _mdp(), n_standard=2, n_greedy=0,
        mcts_config=MCTSConfig(iters_per_decision=12), seed=0,
        parallel=True, cost=be,
    )
    res = tuner.run()
    assert res.cost > 0 and res.plan is not None
    assert be.trainer.version >= 1
    # learned-priced worker entries landed in the master cache with tags,
    # and the workers' serving counters merged back (they pickle zeroed,
    # ship as round activity) — TuneResult.learned_evals reflects them
    assert tuner.cache.terminal_version
    assert be.n_learned_plans > 0
    assert res.learned_evals == be.n_learned_plans
