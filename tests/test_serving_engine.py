"""Continuous-batching correctness: batched decode must equal solo decode.

Regression tests for the shared-`cur` / full-batch-prefill cache corruption
(slots at different lengths clobbered each other's KV / SSM state) and for
``run()`` result semantics.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer
from repro.serving.engine import ServingEngine


def _engine(arch: str, slots: int, *, max_len: int = 32, seed: int = 0):
    cfg = get_config(arch).reduced()
    params = transformer.init_params(cfg, jax.random.PRNGKey(seed))
    return cfg, params, ServingEngine(
        cfg, params, batch_slots=slots, max_len=max_len
    )


def _solo(cfg, params, prompt, max_new, *, max_len: int = 32):
    eng = ServingEngine(cfg, params, batch_slots=1, max_len=max_len)
    eng.submit(np.asarray(prompt, np.int32), max_new_tokens=max_new)
    (done,) = eng.run()
    return done.generated


# mixed lengths force the old shared-cur bug; 3 requests on 2 slots force a
# prefill (request 3) while a neighbour slot is mid-decode — the old
# full-batch `_single_feed` corrupted the neighbour's cache there
@pytest.mark.parametrize("arch", ["granite-3-2b", "falcon-mamba-7b"])
def test_batched_decode_matches_solo(arch):
    cfg, params, eng = _engine(arch, slots=2)
    prompts = [
        np.array([3, 1, 4, 1, 5, 9, 2], np.int32),
        np.array([2, 7], np.int32),
        np.array([6, 6, 6, 6], np.int32),
    ]
    uids = [eng.submit(p, max_new_tokens=5) for p in prompts]
    done = eng.run()
    assert sorted(r.uid for r in done) == uids
    by_uid = {r.uid: r.generated for r in done}
    for uid, prompt in zip(uids, prompts):
        assert by_uid[uid] == _solo(cfg, params, prompt, 5), (
            f"{arch}: batched decode diverged from solo for uid {uid}"
        )


def test_slot_reuse_does_not_leak_state():
    # second occupant of a slot must match a fresh engine (mamba conv/SSM
    # state is not position-masked, so the slot must be reset on assignment)
    cfg, params, eng = _engine("falcon-mamba-7b", slots=1)
    eng.submit(np.array([9, 8, 7], np.int32), max_new_tokens=4)
    eng.run()
    eng.submit(np.array([1, 2], np.int32), max_new_tokens=4)
    (second,) = eng.run()
    assert second.generated == _solo(cfg, params, [1, 2], 4)


def test_run_returns_only_this_calls_completions():
    _, _, eng = _engine("granite-3-2b", slots=2)
    eng.submit(np.array([1, 2], np.int32), max_new_tokens=2)
    first = eng.run()
    assert [r.uid for r in first] == [1]
    eng.submit(np.array([3], np.int32), max_new_tokens=2)
    second = eng.run()
    assert [r.uid for r in second] == [2]  # not [1, 2]
    assert [r.uid for r in eng.finished] == [1, 2]


def test_run_surfaces_still_active_requests():
    _, _, eng = _engine("granite-3-2b", slots=1)
    eng.submit(np.array([5], np.int32), max_new_tokens=8)
    eng.submit(np.array([6], np.int32), max_new_tokens=8)
    done = eng.run(max_steps=3)
    assert done == []
    assert eng.pending() == {"active": 1, "queued": 1}
    done = eng.run()
    assert len(done) == 2
    assert eng.pending() == {"active": 0, "queued": 0}
