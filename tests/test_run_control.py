"""Round-boundary run control: deadlines, cancellation, checkpoints.

Certifies the ``RunController`` seam (``core/run_control.py``) at both
layers:

* unit level, on a fake clock — deadline arming, cancel-only round
  truncation, checkpoint cadence, and per-round checkpoint idempotence;
* engine level, on the dense train cell — an interrupted ensemble run
  checkpoints at a completed round boundary and a resumed run replays
  the exact tail, bit-identical (plan, cost, decisions) to the
  uninterrupted reference, for both the sequential and the pinned-pool
  parallel round paths, plus the evolutionary backend's
  generation-boundary interrupt (best-so-far prefix, no checkpoints).

The daemon-level legs (SIGKILL resume, journal replay, watchdog
degradation) live in ``tests/test_tuner_service.py``.
"""
import pickle

import pytest
from conftest import TRAIN_CELL as CELL
from conftest import make_cell_mdp

from repro.core.autotuner import autotune
from repro.core.run_control import RunController


def _ref(seed=0):
    return autotune(CELL[0], CELL[1], algo="mcts_1s", seed=seed,
                    n_standard=2, n_greedy=1)


# ---------------------------------------------------------------------------
# unit: the controller itself, on a fake clock
# ---------------------------------------------------------------------------
class FakeClock:
    def __init__(self):
        self.t = 100.0

    def __call__(self):
        return self.t


def test_deadline_fires_on_injected_clock():
    clk = FakeClock()
    con = RunController(deadline_s=10.0, clock=clk)
    assert con.should_stop() is None
    clk.t += 9.999
    assert con.should_stop() is None
    clk.t += 0.002
    assert con.should_stop() == "deadline"
    # a deadline NEVER truncates a round — abort_round answers only to
    # cancel, so every deadline checkpoint lands on a canonical boundary
    assert con.abort_round() is False
    assert con.round_truncated is False


def test_cancel_truncates_and_wins_over_deadline():
    clk = FakeClock()
    con = RunController(deadline_s=10.0, clock=clk)
    assert con.abort_round() is False
    con.cancel()
    assert con.cancelled
    assert con.abort_round() is True
    assert con.round_truncated is True
    con.begin_round()  # per-round flag resets at the next boundary
    assert con.round_truncated is False
    clk.t += 100.0  # even past the deadline, cancel is the reported reason
    assert con.should_stop() == "cancelled"


def test_no_deadline_runs_forever():
    con = RunController(clock=FakeClock())
    assert con.deadline is None
    for _ in range(5):
        con.begin_round()
        con.round_done()
    assert con.should_stop() is None and con.n_rounds == 5


def test_checkpoint_cadence_and_per_round_idempotence():
    sink = []
    con = RunController(checkpoint_every=2, checkpoint_fn=sink.append)
    thunk = lambda: {"round": con.n_rounds}  # noqa: E731
    for _ in range(4):
        con.begin_round()
        con.round_done(thunk)
    # cadence: rounds 2 and 4 checkpointed, lazily built from the thunk
    assert [s["round"] for s in sink] == [2, 4]
    assert con.n_checkpoints == 2
    # a final interrupt checkpoint on a cadence round writes nothing new
    assert con.checkpoint(thunk) is True and len(sink) == 2
    # ...but on an off-cadence round it does
    con.begin_round()
    con.round_done(thunk)
    assert con.checkpoint(thunk) is True
    assert [s["round"] for s in sink] == [2, 4, 5]
    # with no sink (or no thunk) there is no checkpoint to report
    assert RunController().checkpoint(thunk) is False
    assert con.checkpoint(None) is False


# ---------------------------------------------------------------------------
# engine: interrupt + resume is bit-identical to the uninterrupted run
# ---------------------------------------------------------------------------
def _cancelling_sink(after: int):
    """A checkpoint sink that cancels its controller after ``after``
    checkpoints land — a deterministic interrupt at an exact round
    boundary (no wall-clock in the loop)."""
    snaps = []
    box = {}

    def fn(snap):
        snaps.append(pickle.dumps(snap))  # like the store: freeze at write
        if len(snaps) >= after:
            box["con"].cancel()

    return snaps, box, fn


@pytest.mark.parametrize("resume_parallel", [False, True])
def test_interrupt_then_resume_bit_identical(resume_parallel):
    ref = _ref()
    rounds_total = len(ref.decisions)
    assert rounds_total > 6

    snaps, box, fn = _cancelling_sink(after=5)
    con = RunController(checkpoint_every=1, checkpoint_fn=fn)
    box["con"] = con
    cut = autotune(CELL[0], CELL[1], algo="mcts_1s", seed=0,
                   n_standard=2, n_greedy=1, controller=con)
    info = cut.stats["interrupted"]
    assert info["reason"] == "cancelled"
    assert info["rounds_done"] == 5 and info["rounds_total"] == rounds_total
    # cancel landed inside round_done's checkpoint → boundary was clean
    assert info["round_truncated"] is False and info["checkpointed"] is True
    assert cut.decisions == ref.decisions[:5]  # best-so-far is a true prefix

    # resume from the frozen checkpoint: the tail replays bit-identically,
    # through the sequential rounds or the pinned-pool parallel rounds
    snap = pickle.loads(snaps[-1])
    res = autotune(CELL[0], CELL[1], algo="mcts_1s", seed=0,
                   n_standard=2, n_greedy=1, resume=snap,
                   parallel=resume_parallel,
                   n_workers=2 if resume_parallel else None)
    assert res.plan == ref.plan and res.cost == ref.cost
    assert res.decisions == ref.decisions
    assert "interrupted" not in (res.stats or {})


def test_uninterrupted_controller_is_inert():
    """A mounted controller that never fires must not perturb the search
    (it reads a clock and an event; it never touches search state)."""
    ref = _ref()
    sink = []
    con = RunController(deadline_s=3600.0, checkpoint_every=3,
                        checkpoint_fn=lambda s: sink.append(True))
    res = autotune(CELL[0], CELL[1], algo="mcts_1s", seed=0,
                   n_standard=2, n_greedy=1, controller=con)
    assert res.plan == ref.plan and res.cost == ref.cost
    assert res.decisions == ref.decisions
    assert "interrupted" not in (res.stats or {})
    assert con.n_rounds == len(ref.decisions) and sink


def test_mid_round_cancel_never_checkpoints_truncated_round():
    """A cancel that lands MID-round (engine/batch.py's iteration poll)
    truncates that round; the truncated round must not be counted,
    checkpointed, or reported as a clean boundary."""
    ref = _ref()
    snaps = []

    con = RunController(checkpoint_every=1,
                        checkpoint_fn=lambda s: snaps.append(len(s["decisions"])))
    con.cancel()  # cancelled before round 1 → the first round truncates
    cut = autotune(CELL[0], CELL[1], algo="mcts_1s", seed=0,
                   n_standard=2, n_greedy=1, controller=con)
    info = cut.stats["interrupted"]
    assert info["reason"] == "cancelled"
    assert info["round_truncated"] is True and info["checkpointed"] is False
    assert snaps == [] and con.n_rounds == 0
    # the engine still finishes the (shortened) round: one decision lands
    assert info["rounds_done"] == 1
    assert len(cut.decisions) == 1
    assert cut.decisions[0]["action"] == ref.decisions[0]["action"]


def test_evolve_backend_deadline_interrupt_is_prefix():
    """The evolutionary backend honors the controller at generation
    boundaries: best-so-far out, decisions a true prefix, and — since an
    evolve replay from scratch is cheap and deterministic — never a
    checkpoint."""
    from repro.core.engine import CachedMDP
    from repro.core.evolve import EvolutionarySearchBackend

    def backend():
        return EvolutionarySearchBackend(population=16, generations=8)

    ref = backend().run(CachedMDP(make_cell_mdp(*CELL)), seed=0)
    assert len(ref.decisions) == 8

    clk = FakeClock()
    con = RunController(deadline_s=1e-9, clock=clk,
                        checkpoint_every=1,
                        checkpoint_fn=lambda s: pytest.fail("no checkpoints"))
    clk.t += 1.0  # deadline already lapsed at the first boundary
    cut = backend().run(CachedMDP(make_cell_mdp(*CELL)), seed=0,
                        controller=con)
    info = cut.stats["interrupted"]
    assert info["reason"] == "deadline" and info["checkpointed"] is False
    assert 0 < info["rounds_done"] < info["rounds_total"] == 8
    assert cut.decisions == ref.decisions[:info["rounds_done"]]
    assert cut.cost == cut.decisions[-1]["best_cost"]
