#!/usr/bin/env python
"""Docs CI gate: the documentation must actually work.

Two checks over README.md and docs/*.md:

1. **Code fences run.**  Every ```python fence is extracted and executed
   verbatim in a fresh subprocess from the repo root (PYTHONPATH=src, like
   the quickstart instructions say).  A fence whose first line contains
   ``docs: no-run`` is skipped — use that for illustrative sketches.
2. **Intra-repo links resolve.**  Every markdown link target that is not
   an URL or a pure anchor must exist on disk, relative to the file (or
   the repo root as a fallback).

    PYTHONPATH=src python scripts/check_docs.py [--list]
"""
from __future__ import annotations

import argparse
import os
import re
import subprocess
import sys

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

FENCE_RE = re.compile(r"^```python[^\n]*\n(.*?)^```\s*$", re.M | re.S)
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def doc_files():
    out = [os.path.join(ROOT, "README.md")]
    docs = os.path.join(ROOT, "docs")
    if os.path.isdir(docs):
        out += sorted(
            os.path.join(docs, f) for f in os.listdir(docs)
            if f.endswith(".md")
        )
    return out


def extract_fences(text):
    for m in FENCE_RE.finditer(text):
        code = m.group(1)
        first = code.lstrip().splitlines()[0] if code.strip() else ""
        if "docs: no-run" in first:
            continue
        yield text[: m.start()].count("\n") + 2, code  # 1-based code start


def run_fence(path, line, code, timeout=900):
    env = dict(os.environ)
    env["PYTHONPATH"] = (
        os.path.join(ROOT, "src")
        + (os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    )
    proc = subprocess.run(
        [sys.executable, "-c", code], cwd=ROOT, env=env,
        capture_output=True, text=True, timeout=timeout,
    )
    rel = os.path.relpath(path, ROOT)
    if proc.returncode != 0:
        return (f"{rel}:{line}: code fence failed "
                f"(exit {proc.returncode})\n{proc.stdout}{proc.stderr}")
    print(f"  ok: {rel}:{line} code fence ran clean")
    return None


def check_links(path, text):
    errors = []
    rel = os.path.relpath(path, ROOT)
    for m in LINK_RE.finditer(text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        target = target.split("#", 1)[0]
        if not target:
            continue
        cand = [
            os.path.normpath(os.path.join(os.path.dirname(path), target)),
            os.path.normpath(os.path.join(ROOT, target)),
        ]
        if not any(os.path.exists(c) for c in cand):
            line = text[: m.start()].count("\n") + 1
            errors.append(f"{rel}:{line}: broken intra-repo link -> {target}")
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--list", action="store_true",
                    help="list fences and links without executing")
    args = ap.parse_args(argv)

    errors = []
    n_fences = n_links = 0
    for path in doc_files():
        with open(path) as f:
            text = f.read()
        n_links += len(LINK_RE.findall(text))
        errors += check_links(path, text)
        for line, code in extract_fences(text):
            n_fences += 1
            if args.list:
                print(f"{os.path.relpath(path, ROOT)}:{line}: "
                      f"{len(code.splitlines())}-line fence")
                continue
            err = run_fence(path, line, code)
            if err:
                errors.append(err)

    print(f"# checked {n_fences} runnable fences, {n_links} links "
          f"across {len(doc_files())} files")
    if errors:
        print("\n".join(f"FAIL: {e}" for e in errors))
        return 1
    print("# docs check passed")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
