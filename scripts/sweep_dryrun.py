#!/usr/bin/env python
"""Run the baseline dry-run for every (arch × shape) cell on both meshes.

Each cell compiles in its own subprocess (repro.launch.dryrun sets the
512-device XLA flag); results land in experiments/measure_cache/ (keyed by
cell + plan) and an index is written to experiments/dryrun/baseline.json.
"""
import argparse
import json
import os
import sys
import time
from concurrent.futures import ThreadPoolExecutor, as_completed

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import cells  # noqa: E402
from repro.core.measure import measure_cell  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--workers", type=int, default=3)
    ap.add_argument("--out", default="experiments/dryrun/baseline.json")
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    todo = [
        (cfg.name, shape.name, mesh)
        for mesh in meshes
        for cfg, shape in cells()
    ]
    print(f"[sweep] {len(todo)} cells, {args.workers} workers")
    results, failures = {}, {}
    t0 = time.time()

    def run(cell):
        arch, shape, mesh = cell
        return cell, measure_cell(arch, shape, mesh, plan=None, timeout=3000)

    with ThreadPoolExecutor(max_workers=args.workers) as ex:
        futs = {ex.submit(run, c): c for c in todo}
        for fut in as_completed(futs):
            cell = futs[fut]
            key = "|".join(cell)
            try:
                _, rec = fut.result()
                results[key] = rec
                print(
                    f"[sweep] ok  {key:55s} step={rec['step_s']*1e3:9.1f}ms "
                    f"dom={rec['dominant']:10s} mfu={rec['mfu']:.3f} "
                    f"compile={rec['compile_s']:.0f}s ({len(results)}/{len(todo)})",
                    flush=True,
                )
            except Exception as e:  # noqa: BLE001
                failures[key] = repr(e)[:500]
                print(f"[sweep] FAIL {key}: {repr(e)[:200]}", flush=True)

    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump({"results": results, "failures": failures}, f, indent=1)
    print(f"[sweep] done in {time.time()-t0:.0f}s: "
          f"{len(results)} ok, {len(failures)} failed -> {args.out}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
