#!/usr/bin/env python
"""ProTuner vs beam vs greedy on one cell, with the noisy cost model —
the paper's head-to-head in miniature (Figs. 7/8).

    PYTHONPATH=src python examples/autotune_compare.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.autotuner import autotune, make_mdp  # noqa: E402

ARCH, SHAPE = "deepseek-67b", "decode_32k"


def main():
    clean = make_mdp(ARCH, SHAPE).cost_model
    print(f"cell: {ARCH} × {SHAPE} (noisy cost model, sigma=0.3)")
    print(f"{'algo':12s} {'model-cost':>12s} {'true-cost':>12s}  plan")
    for algo in ("greedy", "beam", "random", "mcts_10s"):
        mdp = make_mdp(ARCH, SHAPE, noise_sigma=0.3, noise_seed=7)
        res = autotune(ARCH, SHAPE, algo=algo, seed=0, mdp=mdp)
        true = clean.cost(res.plan)
        p = res.plan
        print(f"{algo:12s} {res.cost*1e3:10.2f}ms {true*1e3:10.2f}ms  "
              f"{p.param_strategy},kv={p.kv_dtype},ss={p.seq_shard}")
    print("\n(MCTS evaluates only complete schedules -> robust to the noise;")
    print(" greedy compounds default-completion error at every stage.)")


if __name__ == "__main__":
    main()
