#!/usr/bin/env python
"""End-to-end training driver: a ~100M-param granite-family model trained on
the synthetic pipeline with checkpointing and fault-tolerance hooks.

    PYTHONPATH=src python examples/train_100m.py --steps 300   # full run
    PYTHONPATH=src python examples/train_100m.py --steps 20    # quick look

(On the CPU container a step takes seconds; on a real pod the identical step
function runs under the dry-run's production mesh shardings.)
"""
import argparse
import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import get_config  # noqa: E402
from repro.configs.base import InputShape  # noqa: E402
from repro.core.space import SchedulePlan  # noqa: E402
from repro.training import optimizer as optim  # noqa: E402
from repro.training.trainer import Trainer, TrainerConfig  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="/tmp/repro_100m")
    args = ap.parse_args()

    # ~100M params: granite family, scaled
    cfg = dataclasses.replace(
        get_config("granite-3-2b"),
        name="granite-100m",
        n_layers=12,
        d_model=768,
        n_heads=12,
        n_kv_heads=4,
        head_dim=64,
        d_ff=2048,
        vocab_size=32768,
        dtype="float32",
    )
    print(f"model: {cfg.name}  params={cfg.param_count()/1e6:.1f}M")
    shape = InputShape("train", args.seq, args.batch, "train")
    plan = SchedulePlan(microbatches=2, remat="dots", grad_comm="fp32",
                        opt_dtype="float32")
    tc = TrainerConfig(total_steps=args.steps, ckpt_every=max(args.steps // 4, 10),
                       ckpt_dir=args.ckpt, log_every=10)
    oc = optim.OptimizerConfig(peak_lr=3e-4, warmup_steps=20,
                               total_steps=args.steps)
    trainer = Trainer(cfg, shape, plan, tc, opt_cfg=oc)
    params, _, step = trainer.run()
    for rec in trainer.metrics_log:
        print(f"step {rec['step']:5d}  loss {rec['loss']:.4f}  "
              f"lr {rec['lr']:.2e}  {rec['step_time_s']*1e3:.0f} ms/step")
    print(f"finished at step {step}; checkpoints in {args.ckpt}")

    # demonstrate the failure path: elastic plan from the last checkpoint
    plan2 = trainer.handle_failure([f"h{i}" for i in range(7)],
                                   chips_per_host=4, model_parallel=4)
    print(f"elastic restart plan after losing 1/8 hosts: dp={plan2.data_parallel} "
          f"restart_step={plan2.restart_step}")


if __name__ == "__main__":
    main()
