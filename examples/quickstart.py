#!/usr/bin/env python
"""Quickstart: autotune a schedule with ProTuner (MCTS), inspect its roofline
terms, and run a few training steps with it — all on CPU in ~1 minute.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.configs.base import InputShape  # noqa: E402
from repro.core.autotuner import autotune, make_mdp  # noqa: E402
from repro.core.space import SchedulePlan  # noqa: E402
from repro.data.pipeline import Pipeline  # noqa: E402
from repro.models import transformer  # noqa: E402
from repro.training import optimizer as optim  # noqa: E402
from repro.training.train_step import make_train_step  # noqa: E402
import jax  # noqa: E402


def main():
    # --- 1. ProTuner: MCTS ensemble (15 standard + 1 greedy) over the
    #        schedule MDP for the REAL phi-3.5-MoE × train_4k cell ---
    arch, shape_name = "phi3.5-moe-42b-a6.6b", "train_4k"
    print(f"== autotuning {arch} × {shape_name} (256-chip v5e pod) ==")
    res = autotune(arch, shape_name, algo="mcts_1s", seed=0)
    terms = make_mdp(arch, shape_name).cost_model.terms(res.plan)
    print(f"best schedule ({res.n_evals} cost evals, {res.wall_time_s:.1f}s):")
    for k, v in res.plan.to_dict().items():
        print(f"    {k:16s} = {v}")
    print(f"estimated step: {terms.step_s*1e3:.1f} ms "
          f"(compute {terms.compute_s*1e3:.0f} / memory {terms.memory_s*1e3:.0f} "
          f"/ collective {terms.collective_s*1e3:.0f}) "
          f"dominant={terms.dominant} MFU={terms.details['mfu']:.3f}")

    # --- 2. train a tiny same-family model with the plan's knobs ---
    print("\n== smoke-training the reduced config with the tuned knobs ==")
    cfg = get_config(arch).reduced()
    shape = InputShape("smoke", 32, 4, "train")
    plan = SchedulePlan(microbatches=2, remat=res.plan.remat,
                        opt_dtype=res.plan.opt_dtype)
    oc = optim.OptimizerConfig(peak_lr=5e-3, warmup_steps=3, total_steps=20)
    step = jax.jit(make_train_step(cfg, shape, plan, oc))
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    opt_state = optim.init_opt_state(params, oc)
    pipe = Pipeline(cfg, shape)
    for i in range(12):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(i).items()}
        params, opt_state, m = step(params, opt_state, batch)
        if i % 3 == 0:
            print(f"    step {i:3d}  loss {float(m['loss']):.4f}")
    print("done.")


if __name__ == "__main__":
    main()
