#!/usr/bin/env python
"""Batched serving demo: continuous-batching engine over a token-input arch.

    PYTHONPATH=src python examples/serve_batched.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.models import transformer  # noqa: E402
from repro.serving.engine import ServingEngine  # noqa: E402


def main():
    cfg = get_config("granite-3-2b").reduced()
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, batch_slots=4, max_len=48)
    rng = np.random.default_rng(0)
    for i in range(8):  # more requests than slots: exercises slot recycling
        plen = int(rng.integers(1, 6))
        eng.submit(rng.integers(0, cfg.vocab_size, plen), max_new_tokens=8)
    done = eng.run()
    for r in sorted(done, key=lambda r: r.uid):
        print(f"req {r.uid}: prompt={r.prompt.tolist()} -> {r.generated}")
    print(f"completed {len(done)}/8 requests with 4 slots")


if __name__ == "__main__":
    main()
